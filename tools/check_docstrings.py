#!/usr/bin/env python
"""pydocstyle-lite: fail CI when a public API lacks a docstring.

Usage:
    python tools/check_docstrings.py src/repro/core src/repro/graphio

Thin compatibility wrapper over the ``docstrings`` checker of the
repro-lint suite (``tools/analyze.py --check docstrings``) — the
checker itself lives in ``tools/analyzers/docstrings.py`` (GH501).
Kept so existing invocations and muscle memory keep working; new
tooling should call ``tools/analyze.py`` directly.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from analyze import run  # noqa: E402


def main(argv: list[str]) -> int:
    """Run the GH501 docstring checker over the given roots (defaults
    to the historical core/ + graphio/ pair); print findings and
    return 1 if any public API is undocumented."""
    roots = argv or ["src/repro/core", "src/repro/graphio"]
    findings, _suppressed = run(roots, ["docstrings"], all_files=True)
    for f in findings:
        print(f"{f.path}:{f.line} {f.code} {f.message}")
    if findings:
        print(f"\n{len(findings)} public APIs without docstrings "
              f"(shapes/units/thread-safety belong there — see "
              f"docs/ARCHITECTURE.md)", file=sys.stderr)
        return 1
    print(f"docstring check OK: {', '.join(roots)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
