#!/usr/bin/env python
"""pydocstyle-lite: fail CI when a public API lacks a docstring.

Usage:
    python tools/check_docstrings.py src/repro/core src/repro/graphio

Walks the given directories and reports every public module, class,
function, and method (names not starting with "_", excluding nested
defs) that has no docstring.  This enforces the repo convention that
public ``core/`` and ``graphio/`` APIs document their array shapes
(``[V,Q]``, ``[Q,BE]``), units (bytes vs elements), and thread-safety
(docs/ARCHITECTURE.md).  Exit code 1 on any finding.

Deliberately tiny (stdlib ``ast`` only) so it runs anywhere the repo
runs — the container has no pydocstyle.
"""
from __future__ import annotations

import ast
import os
import sys


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _check_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    missing: list[str] = []
    if ast.get_docstring(tree) is None:
        missing.append(f"{path}:1 module docstring")

    def walk(node: ast.AST, scope: str, top: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                name = child.name
                qual = f"{scope}{name}"
                is_cls = isinstance(child, ast.ClassDef)
                if _is_public(name) and ast.get_docstring(child) is None:
                    kind = "class" if is_cls else "def"
                    missing.append(f"{path}:{child.lineno} {kind} {qual}")
                # descend into PUBLIC classes for their methods — private
                # classes and function bodies are implementation detail
                if is_cls and _is_public(name):
                    walk(child, f"{qual}.", top=False)

    walk(tree, "", top=True)
    return missing


def main(argv: list[str]) -> int:
    """Scan every ``*.py`` under the given roots; print findings and
    return 1 if any public API is undocumented."""
    roots = argv or ["src/repro/core", "src/repro/graphio"]
    findings: list[str] = []
    for root in roots:
        for dirpath, _dirs, files in os.walk(root):
            for fn in sorted(files):
                if fn.endswith(".py"):
                    findings += _check_file(os.path.join(dirpath, fn))
    for line in findings:
        print(line)
    if findings:
        print(f"\n{len(findings)} public APIs without docstrings "
              f"(shapes/units/thread-safety belong there — see "
              f"docs/ARCHITECTURE.md)", file=sys.stderr)
        return 1
    print(f"docstring check OK: {', '.join(roots)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
