"""Shared machinery for the repro-lint checkers: findings, suppression
comments, and file walking.

A finding is one ``path:line CODE message`` record.  Suppressions are
inline comments of the form::

    # lint: allow(GH205): inbox is filled in rank order at construction

and may sit on the finding's own line (trailing comment) or on the line
directly above it.  The justification after the colon is mandatory — an
allow without one is itself a finding (GH001), so every suppressed site
carries its reviewable reason in the source.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re

#: suppression-mechanism findings (emitted here, not by a checker)
CODES = {
    "GH001": "lint: allow(...) without a written justification",
    "GH002": "unused suppression — no finding matches this allow",
}

_ALLOW_RE = re.compile(
    r"#\s*lint:\s*allow\(\s*([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)\s*\)"
    r"(?::\s*(\S.*))?")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One analyzer finding, renderable as ``path:line code message``."""

    path: str
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.code} {self.message}"


@dataclasses.dataclass
class Allow:
    """One parsed ``# lint: allow(...)`` comment."""

    line: int
    codes: tuple[str, ...]
    reason: str
    used: bool = False


class Suppressions:
    """Per-file suppression table.

    ``filter(findings)`` drops findings allowed at their line (or the
    line above) and marks the allow as used; ``problems()`` yields GH001
    findings for justification-less allows, and — when asked — GH002 for
    allows that matched nothing (stale suppressions rot fast; CI keeps
    them honest).
    """

    def __init__(self, path: str, text: str):
        self.path = path
        self.allows: list[Allow] = []
        for lineno, raw in enumerate(text.splitlines(), start=1):
            m = _ALLOW_RE.search(raw)
            if m:
                codes = tuple(c.strip() for c in m.group(1).split(","))
                self.allows.append(
                    Allow(line=lineno, codes=codes,
                          reason=(m.group(2) or "").strip()))

    def _match(self, finding: Finding) -> Allow | None:
        for a in self.allows:
            if finding.code in a.codes and a.line in (finding.line,
                                                      finding.line - 1):
                return a
        return None

    def filter(self, findings: list[Finding]) -> tuple[list[Finding], int]:
        """(kept findings, count suppressed); marks matching allows used."""
        kept: list[Finding] = []
        suppressed = 0
        for f in findings:
            a = self._match(f)
            if a is None:
                kept.append(f)
            else:
                a.used = True
                suppressed += 1
        return kept, suppressed

    def problems(self, report_unused: bool) -> list[Finding]:
        out = []
        for a in self.allows:
            if not a.reason:
                out.append(Finding(self.path, a.line, "GH001",
                                   "suppression needs a justification: "
                                   "# lint: allow(CODE): <why this is safe>"))
            elif report_unused and not a.used:
                out.append(Finding(self.path, a.line, "GH002",
                                   f"unused suppression for "
                                   f"{', '.join(a.codes)} — remove it"))
        return out


def load_source(path: str) -> tuple[str, ast.AST]:
    """(text, parsed tree) for one file; SyntaxError propagates."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    return text, ast.parse(text, filename=path)


def iter_py_files(paths: list[str]) -> list[str]:
    """Expand files/directories into a sorted list of ``*.py`` files."""
    out: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, _dirs, files in os.walk(p):
                for fn in files:
                    if fn.endswith(".py"):
                        out.append(os.path.join(dirpath, fn))
        elif p.endswith(".py"):
            out.append(p)
    return sorted(set(out))


def norm_relpath(path: str) -> str:
    """Forward-slash path for target matching, relative to the repo root
    when the file sits under one (otherwise as given)."""
    rel = os.path.normpath(path).replace(os.sep, "/")
    if "src/repro/" in rel:
        rel = "src/repro/" + rel.split("src/repro/", 1)[1]
    return rel


def suffix_match(relpath: str, suffixes: tuple[str, ...]) -> bool:
    """True when ``relpath`` ends with (or sits under) one of the target
    suffixes — ``"src/repro/core/"`` matches the whole package,
    ``"src/repro/core/comm.py"`` one module."""
    for s in suffixes:
        if s.endswith("/"):
            if s in relpath + "/" or relpath.startswith(s):
                return True
        elif relpath.endswith(s):
            return True
    return False


def is_public(name: str) -> bool:
    return not name.startswith("_")
