"""GH3xx — staged-write atomicity checker.

Checkpoint and manifest writers must follow the staged protocol (PR 6,
DESIGN.md §12): write into a ``*.tmp`` / pid-suffixed staging path,
flush + ``os.fsync`` the staged bytes, then publish with the atomic
``os.replace`` — a crash at any point leaves either the old file or the
new one, never a torn mix.  This checker patrols the modules that own
durable state (``TARGET_SUFFIXES``):

  GH301  bare write to a non-staged path: ``open(p, "w"/"wb"/"a")``,
         ``np.save``/``np.savez``, ``shutil.copy*`` or ``os.link`` whose
         destination expression mentions no staging name (``tmp``).
         Writes routed through a parameter path the caller stages carry
         a ``# lint: allow(GH301): why`` justification instead.
  GH302  ``os.replace`` publish in a function that staged bytes with
         ``open(...)`` but never ``os.fsync``-ed them — after a crash
         the *rename* may survive while the data didn't hit the platter,
         which is exactly the torn state the protocol exists to prevent.

The tmp-ness test is syntactic (the path expression's source contains a
name with ``tmp`` in it), which matches the repo convention: staging
paths are always built as ``path + ".tmp"`` / ``step_N.tmp.<pid>``.
"""
from __future__ import annotations

import ast

from .common import Finding, suffix_match

CODES = {
    "GH301": "non-staged write on a durable path (no tmp staging)",
    "GH302": "os.replace publish without fsync of the staged bytes",
}

#: modules that own durable state (checkpoints, manifests, spill files,
#: tile stores)
TARGET_SUFFIXES = (
    "src/repro/core/checkpoint.py",
    "src/repro/train/checkpoint.py",
    "src/repro/core/vstate.py",
    "src/repro/graphio/formats.py",
)

_WRITE_MODES = ("w", "wb", "a", "ab", "w+", "wb+", "x", "xb")
_COPY_FUNCS = {("shutil", "copy"), ("shutil", "copy2"),
               ("shutil", "copyfile"), ("os", "link"), ("os", "symlink")}
_NP_SAVERS = {("np", "save"), ("np", "savez"), ("np", "savez_compressed"),
              ("numpy", "save"), ("numpy", "savez"),
              ("numpy", "savez_compressed")}


def applies(relpath: str) -> bool:
    return suffix_match(relpath, TARGET_SUFFIXES)


def _dotted(func: ast.AST) -> tuple[str, ...]:
    parts: list[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _mentions_tmp(node: ast.AST) -> bool:
    """True when the path expression's source names a staging path —
    a ``tmp`` identifier/attribute or a string containing ``tmp``/``.bak``."""
    src = ast.unparse(node).lower()
    return "tmp" in src or ".bak" in src


def _open_write(node: ast.Call) -> ast.AST | None:
    """The path argument of a write-mode ``open(...)`` call, else None."""
    if _dotted(node.func) != ("open",) or not node.args:
        return None
    mode = None
    if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant):
        mode = node.args[1].value
    for kw in node.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    if isinstance(mode, str) and any(m in mode for m in ("w", "a", "x", "+")):
        return node.args[0]
    return None


def check_file(path: str, text: str, tree: ast.AST) -> list[Finding]:
    """Run the atomicity checker over one parsed module."""
    findings: list[Finding] = []

    functions = [n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in functions:
        # own statements only — nested defs are scanned as their own fn
        nested_lines: set[int] = set()
        for sub in ast.walk(fn):
            if sub is not fn and isinstance(
                    sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for inner in ast.walk(sub):
                    if hasattr(inner, "lineno"):
                        nested_lines.add(inner.lineno)

        # handles bound by ``with open(<tmp path>, ...) as f`` — writing
        # through them (np.savez(f, ...)) IS the staged idiom
        staged_handles: set[str] = set()
        for node in ast.walk(fn):
            if getattr(node, "lineno", None) in nested_lines:
                continue
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    ctx = item.context_expr
                    if (isinstance(ctx, ast.Call)
                            and _dotted(ctx.func) == ("open",)
                            and ctx.args and _mentions_tmp(ctx.args[0])
                            and isinstance(item.optional_vars, ast.Name)):
                        staged_handles.add(item.optional_vars.id)
            # in-memory buffers are not durable writes either
            elif isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                vd = _dotted(node.value.func)
                if vd and vd[-1] in ("BytesIO", "StringIO"):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            staged_handles.add(t.id)

        opened_nontmp: list[tuple[int, str]] = []
        staged_open = False
        has_fsync = False
        replaces: list[int] = []
        for node in ast.walk(fn):
            if getattr(node, "lineno", None) in nested_lines:
                continue
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            path_arg = _open_write(node)
            if path_arg is not None:
                if _mentions_tmp(path_arg):
                    staged_open = True
                else:
                    opened_nontmp.append(
                        (node.lineno,
                         f"open({ast.unparse(path_arg)}, write mode)"))
            elif d in _NP_SAVERS and node.args:
                if isinstance(node.args[0], ast.Name) \
                        and node.args[0].id in staged_handles:
                    pass          # memory buffer / staged handle
                elif not _mentions_tmp(node.args[0]):
                    opened_nontmp.append(
                        (node.lineno, f"{'.'.join(d)}(...)"))
                else:
                    staged_open = True
            elif d in _COPY_FUNCS and len(node.args) >= 2:
                if not _mentions_tmp(node.args[1]):
                    opened_nontmp.append(
                        (node.lineno,
                         f"{'.'.join(d)}(dst={ast.unparse(node.args[1])})"))
            elif d == ("os", "fsync"):
                has_fsync = True
            elif d == ("os", "replace") or d == ("os", "rename"):
                replaces.append(node.lineno)

        for line, what in opened_nontmp:
            findings.append(Finding(
                path, line, "GH301",
                f"{what} writes a durable path without tmp staging — "
                f"stage to *.tmp, fsync, then os.replace"))
        if replaces and staged_open and not has_fsync:
            for line in replaces:
                findings.append(Finding(
                    path, line, "GH302",
                    "publish via os.replace but the staged bytes were "
                    "never fsync-ed — a crash can persist the rename "
                    "without the data"))
    return findings
