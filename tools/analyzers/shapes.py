"""GH4xx — docstring shape-contract checker.

docs/ARCHITECTURE.md mandates that public ``core/``/``kernels/`` APIs
document their array shapes in the bracket grammar the repo uses
everywhere: ``[V, Q]``, ``[Q, BE]``, ``[K+1]``, ``[V(, Q)]`` (optional
trailing axis).  This checker parses that grammar out of docstrings and
enforces:

  GH401  a public function with array-annotated params/returns whose
         docstring carries no shape token at all
  GH402  axis-order mismatch between caller and callee: a function
         documented ``[A, B]`` calls a same-module helper documented
         ``[B, A]`` with no transpose in sight
  GH403  an axis name outside the module vocabulary (typo'd grammar)

Axis vocabulary (docs/ARCHITECTURE.md "Shape vocabulary" + kernels):
V vertices · E edges (edge_cap) · R tile rows (row_cap) · Q query
columns · Qa active-query columns · Qp padded query columns · U updated
vertices · K intervals (or gather capacity) · P tiles · N ranks ·
B generic block · BE/BR edge/row block sizes.  Integer items (``[2]``)
and ``+/- <int>`` offsets (``[K+1]``) are part of the grammar; tokens
with any non-grammar item (``[lo, hi)``, ``list[Tile]``) are prose, not
shapes, and are ignored.
"""
from __future__ import annotations

import ast
import re

from .common import Finding, is_public, suffix_match

CODES = {
    "GH401": "public array API documents no shape",
    "GH402": "caller/callee axis order mismatch without a transpose",
    "GH403": "unknown axis name in a shape token",
}

TARGET_SUFFIXES = (
    "src/repro/core/",
    "src/repro/kernels/",
)

VOCAB = {"V", "E", "R", "Q", "U", "K", "P", "N", "B",
         "BE", "BR", "Qa", "Qp"}

_TOKEN_RE = re.compile(r"\[([^\[\]]{1,40})\]")
_ITEM_RE = re.compile(r"^([A-Z][A-Za-z]?)(\s*[+-]\s*\d+)?$")
_TRANSPOSE_RE = re.compile(r"\.T\b|transpose|swapaxes|moveaxis|\.mT\b")
_ARRAYISH_RE = re.compile(r"ndarray|Array|jnp\.|jax\.")


def applies(relpath: str) -> bool:
    return suffix_match(relpath, TARGET_SUFFIXES)


def parse_shape_tokens(doc: str) -> list[tuple[str, ...]]:
    """Extract every shape token from a docstring as a tuple of axis
    names; integer items are kept as their digits, offsets stripped
    (``[K+1]`` -> ``("K",)``).  Non-grammar brackets are skipped."""
    out: list[tuple[str, ...]] = []
    for m in _TOKEN_RE.finditer(doc or ""):
        body = m.group(1).replace("(", "").replace(")", "")
        items = [it.strip() for it in body.split(",") if it.strip()]
        if not items:
            continue
        axes: list[str] = []
        for it in items:
            if it.isdigit():
                axes.append(it)
                continue
            im = _ITEM_RE.match(it)
            if im is None:
                axes = []
                break
            axes.append(im.group(1))
        if axes:
            out.append(tuple(axes))
    return out


def _annotation_is_array(node: ast.AST | None) -> bool:
    if node is None:
        return False
    return bool(_ARRAYISH_RE.search(ast.unparse(node)))


def _function_records(tree: ast.AST):
    """Yield (fn node, qualname, is_public_api) for module functions and
    methods of public classes (the same surface the docstring checker
    enforces)."""
    for node in ast.iter_child_nodes(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.name, is_public(node.name)
        elif isinstance(node, ast.ClassDef):
            for sub in ast.iter_child_nodes(node):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield (sub, f"{node.name}.{sub.name}",
                           is_public(node.name) and is_public(sub.name))


def check_file(path: str, text: str, tree: ast.AST) -> list[Finding]:
    """Run the shape-contract checker over one parsed module."""
    findings: list[Finding] = []
    #: bare function/method name -> ordered 2-axis pairs it documents
    declared_pairs: dict[str, set[tuple[str, str]]] = {}
    records = list(_function_records(tree))

    for fn, qual, public in records:
        doc = ast.get_docstring(fn)
        tokens = parse_shape_tokens(doc or "")
        named = [t for t in tokens
                 if len(t) == 2 and t[0] in VOCAB and t[1] in VOCAB
                 and t[0] != t[1]]
        if named:
            declared_pairs.setdefault(fn.name, set()).update(
                (a, b) for a, b in named)
        for t in tokens:
            for ax in t:
                if not ax.isdigit() and ax not in VOCAB:
                    findings.append(Finding(
                        path, fn.lineno, "GH403",
                        f"{qual}: axis {ax!r} is not in the shape "
                        f"vocabulary ({', '.join(sorted(VOCAB))}) — "
                        f"typo, or extend the grammar in "
                        f"tools/analyzers/shapes.py + ARCHITECTURE.md"))
        if not public:
            continue
        args = list(fn.args.posonlyargs) + list(fn.args.args) \
            + list(fn.args.kwonlyargs)
        has_array = any(_annotation_is_array(a.annotation) for a in args) \
            or _annotation_is_array(fn.returns)
        if has_array and not tokens:
            findings.append(Finding(
                path, fn.lineno, "GH401",
                f"{qual} takes/returns arrays but documents no shape — "
                f"annotate like [V, Q] (docs/ARCHITECTURE.md)"))

    # caller/callee axis-order cross-check
    for fn, qual, public in records:
        mine = declared_pairs.get(fn.name)
        if not mine:
            continue
        src = ast.get_source_segment(text, fn) or ""
        if _TRANSPOSE_RE.search(src):
            continue     # transpose evidence present — assume intentional
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = None
            if isinstance(node.func, ast.Name):
                callee = node.func.id
            elif (isinstance(node.func, ast.Attribute)
                  and isinstance(node.func.value, ast.Name)
                  and node.func.value.id == "self"):
                callee = node.func.attr
            if callee is None or callee == fn.name:
                continue
            theirs = declared_pairs.get(callee)
            if not theirs:
                continue
            for a, b in mine:
                if (b, a) in theirs and (a, b) not in theirs:
                    findings.append(Finding(
                        path, node.lineno, "GH402",
                        f"{qual} documents [{a}, {b}] but calls "
                        f"{callee} documented [{b}, {a}] with no "
                        f"transpose — axis order disagrees"))
    return findings
