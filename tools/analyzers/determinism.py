"""GH2xx — cross-rank determinism lint.

The cluster exchange merges every rank's update set in rank order and
asserts bit-identity against the single-process engine, so any code that
produces frames, plans, assignments or checkpoints must be a pure
function of its inputs — no hash-order iteration, no directory-listing
order, no wall-clock or RNG leaks.  This checker patrols the modules on
that critical path (``TARGET_SUFFIXES``) for the syntactic hazards:

  GH201  iteration over a ``set``/``frozenset`` (hash order)
  GH202  ``os.listdir``/``os.scandir``/``glob`` result used unsorted
  GH203  ``time.time``/``datetime.now``/``random``/``np.random`` call
         (``time.monotonic``/``perf_counter`` are fine: measurements ride
         the fixed-width exchange envelope, never the frame body)
  GH204  ``sum()`` over an unordered collection (float accumulation
         order changes the bits)
  GH205  iteration over dict views (``.values()``/``.items()``/
         ``.keys()``) — insertion order must be *proven* deterministic
         across ranks (e.g. built in rank order), or sorted

Wrapping the iterable in ``sorted(...)`` clears GH201/GH202/GH205.
Sites whose order is provably rank-deterministic or folded commutatively
carry a ``# lint: allow(GH20x): why`` justification instead.
"""
from __future__ import annotations

import ast

from .common import Finding, suffix_match

CODES = {
    "GH201": "iteration over a set (hash order is not cross-rank stable)",
    "GH202": "unsorted os.listdir/glob result",
    "GH203": "wall-clock or RNG call in deterministic-path code",
    "GH204": "sum() over an unordered collection",
    "GH205": "dict-view iteration without sorted() or a determinism proof",
}

#: the bit-identity-critical modules (frames, plans, merges, manifests)
TARGET_SUFFIXES = (
    "src/repro/core/comm.py",
    "src/repro/core/transport.py",
    "src/repro/core/distributed.py",
    "src/repro/runtime/scheduler.py",
    "src/repro/runtime/elastic.py",
    "src/repro/core/checkpoint.py",
)

_LISTING_CALLS = {("os", "listdir"), ("os", "scandir"), ("glob", "glob"),
                  ("glob", "iglob")}
_CLOCK_RNG = {("time", "time"), ("datetime", "now"), ("datetime", "utcnow")}
_DICT_VIEWS = {"values", "items", "keys"}


def applies(relpath: str) -> bool:
    return suffix_match(relpath, TARGET_SUFFIXES)


def _dotted(func: ast.AST) -> tuple[str, ...]:
    """('os', 'listdir') for ``os.listdir``; () when not a plain dotted name."""
    parts: list[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


class _SetTracker(ast.NodeVisitor):
    """Per-function names bound to set-typed expressions (one level of
    local inference: ``s = set()``, ``s: set[int] = ...``, set literals
    and comprehensions)."""

    def __init__(self):
        self.set_names: set[str] = set()

    @staticmethod
    def _is_set_expr(node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            return d in (("set",), ("frozenset",))
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        if self._is_set_expr(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.set_names.add(t.id)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        ann = ast.unparse(node.annotation) if node.annotation else ""
        if (isinstance(node.target, ast.Name)
                and (ann.startswith("set") or ann.startswith("frozenset")
                     or (node.value is not None
                         and self._is_set_expr(node.value)))):
            self.set_names.add(node.target.id)
        self.generic_visit(node)


def _strip_neutralizers(node: ast.AST) -> ast.AST:
    """Peel ``list(...)``/``tuple(...)``/``enumerate(...)``/``reversed(...)``
    wrappers — they preserve the inner order.  ``sorted(...)`` is NOT
    peeled: it fixes the order, so the subtree below it is safe."""
    while (isinstance(node, ast.Call)
           and _dotted(node.func) in (("list",), ("tuple",), ("enumerate",),
                                      ("reversed",), ("iter",))
           and node.args):
        node = node.args[0]
    return node


def _under_sorted(node: ast.AST, parents: dict) -> bool:
    cur = parents.get(id(node))
    while cur is not None:
        if isinstance(cur, ast.Call) and _dotted(cur.func) in (
                ("sorted",), ("min",), ("max",), ("len",), ("any",), ("all",),
                ("sum",), ("set",), ("frozenset",)):
            return True
        cur = parents.get(id(cur))
    return False


def check_file(path: str, text: str, tree: ast.AST) -> list[Finding]:
    """Run the determinism lint over one parsed module."""
    findings: list[Finding] = []
    parents: dict[int, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[id(child)] = node

    # set-name inference per enclosing function
    set_names: set[str] = set()
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        tracker = _SetTracker()
        tracker.visit(fn)
        set_names |= tracker.set_names

    def _flag_iter_expr(iter_node: ast.AST) -> None:
        """Flag hazards in one iteration source expression."""
        base = _strip_neutralizers(iter_node)
        candidates = [base]
        if isinstance(base, ast.Tuple):          # (*a.values(), *b.values())
            candidates = [_strip_neutralizers(
                e.value if isinstance(e, ast.Starred) else e)
                for e in base.elts]
        for cand in candidates:
            if _under_sorted(cand, parents):
                continue   # sorted()/len()/membership fixes or ignores order
            if isinstance(cand, (ast.Set, ast.SetComp)):
                findings.append(Finding(
                    path, cand.lineno, "GH201",
                    "iterating a set — hash order is not deterministic "
                    "across ranks/runs; sort it"))
            elif isinstance(cand, ast.Call):
                d = _dotted(cand.func)
                if d in (("set",), ("frozenset",)):
                    findings.append(Finding(
                        path, cand.lineno, "GH201",
                        "iterating a set — sort it"))
                elif len(d) >= 2 and (d[-2], d[-1]) in _LISTING_CALLS:
                    findings.append(Finding(
                        path, cand.lineno, "GH202",
                        f"{'.'.join(d)}() order is filesystem-dependent — "
                        f"wrap in sorted()"))
                elif (isinstance(cand.func, ast.Attribute)
                      and cand.func.attr in _DICT_VIEWS
                      and not cand.args):
                    findings.append(Finding(
                        path, cand.lineno, "GH205",
                        f".{cand.func.attr}() iteration follows insertion "
                        f"order — prove it rank-deterministic or sort"))
            elif isinstance(cand, ast.Name) and cand.id in set_names:
                findings.append(Finding(
                    path, cand.lineno, "GH201",
                    f"iterating set {cand.id!r} — hash order is not "
                    f"deterministic across ranks/runs; sort it"))

    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            _flag_iter_expr(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                _flag_iter_expr(gen.iter)
        elif isinstance(node, ast.Call):
            d = _dotted(node.func)
            if len(d) >= 2 and (d[-2], d[-1]) in _CLOCK_RNG:
                findings.append(Finding(
                    path, node.lineno, "GH203",
                    f"{'.'.join(d)}() in deterministic-path code — "
                    f"measurements belong in the exchange envelope"))
            elif d[:1] == ("random",) or d[:2] == ("np", "random") \
                    or d[:2] == ("numpy", "random"):
                findings.append(Finding(
                    path, node.lineno, "GH203",
                    f"{'.'.join(d)}() RNG call in deterministic-path code"))
            elif d == ("sum",) and node.args:
                arg = node.args[0]
                unordered = isinstance(arg, (ast.Set, ast.SetComp))
                if isinstance(arg, ast.Call):
                    ad = _dotted(arg.func)
                    unordered = (ad in (("set",), ("frozenset",))
                                 or (isinstance(arg.func, ast.Attribute)
                                     and arg.func.attr in _DICT_VIEWS
                                     and not arg.args))
                if isinstance(arg, ast.GeneratorExp):
                    src = _strip_neutralizers(arg.generators[0].iter)
                    unordered = (
                        isinstance(src, (ast.Set, ast.SetComp))
                        or (isinstance(src, ast.Call)
                            and (_dotted(src.func) in (("set",),
                                                       ("frozenset",))
                                 or (isinstance(src.func, ast.Attribute)
                                     and src.func.attr in _DICT_VIEWS
                                     and not src.args)))
                        or (isinstance(src, ast.Name)
                            and src.id in set_names))
                if isinstance(arg, ast.Name) and arg.id in set_names:
                    unordered = True
                if unordered:
                    findings.append(Finding(
                        path, node.lineno, "GH204",
                        "sum() over an unordered collection — float "
                        "accumulation order changes the bits; sort first"))
    return findings
