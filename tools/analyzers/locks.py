"""GH1xx — lock-discipline race checker.

Classes opt in by declaring which attributes their lock(s) guard::

    class EdgeCache:
        _guarded_by = {"_entries": "_lock", "stats": ("_lock",)}

Values are a lock attribute name or a tuple of acceptable ones (for
aliased locks, e.g. a ``threading.Condition(self._lock)`` that acquires
the same underlying lock).  The checker then proves, per method, that
every read/write of a guarded attribute happens while one of its locks
is held:

  * ``with self._lock:`` (and ``with self._locks[key]:``) blocks hold
    the named lock for their body;
  * **thread entry points** — public methods, dunders, methods passed as
    callbacks (``Thread(target=self._m)``, ``pool.submit(self._m)``),
    functions nested inside methods (prefetch workers, background-timer
    bodies), and private methods never called inside the class — are
    assumed to run with NO lock held;
  * private helpers called only from locked contexts inherit the
    intersection of the locks guaranteed at every call site (a fixpoint
    over the intra-class call graph), so ``_insert_locked``-style
    caller-holds-lock helpers need no annotation;
  * ``__init__`` / ``__post_init__`` — and private helpers reachable
    *only* from them — are exempt: the object is not yet shared.

Codes:
  GH101  guarded attribute accessed without holding its lock
  GH102  ``_guarded_by`` names an attribute the class never uses
  GH103  ``_guarded_by`` must be a literal dict of str -> str | tuple
"""
from __future__ import annotations

import ast
import dataclasses

from .common import Finding

CODES = {
    "GH101": "guarded attribute accessed without its lock",
    "GH102": "_guarded_by entry never accessed in the class",
    "GH103": "malformed _guarded_by declaration",
}

#: no target filter — any file may declare _guarded_by; files without a
#: declaration produce no work and no findings.
TARGET_SUFFIXES: tuple[str, ...] | None = None

EXEMPT_METHODS = ("__init__", "__post_init__")


def applies(relpath: str) -> bool:
    return True


@dataclasses.dataclass
class _Access:
    attr: str
    line: int
    held: frozenset


@dataclasses.dataclass
class _CallSite:
    callee: str
    held: frozenset


@dataclasses.dataclass
class _MethodScan:
    name: str
    public: bool
    nested: bool                      # a def nested inside a method body
    accesses: list = dataclasses.field(default_factory=list)
    calls: list = dataclasses.field(default_factory=list)
    #: method names referenced as ``self.m`` outside call position —
    #: callbacks / thread targets; they may run unlocked at any time
    callbacks: set = dataclasses.field(default_factory=set)


def _parse_guarded_by(cls: ast.ClassDef) -> tuple[dict | None, list[Finding],
                                                  int]:
    """Extract the literal ``_guarded_by`` dict; (mapping, findings, line)."""
    for stmt in cls.body:
        target = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            target = stmt.targets[0]
        elif isinstance(stmt, ast.AnnAssign):
            target = stmt.target
        if not (isinstance(target, ast.Name)
                and target.id == "_guarded_by"):
            continue
        value = stmt.value
        line = stmt.lineno
        bad = [Finding("", line, "GH103",
                       "_guarded_by must be a literal dict of "
                       "str -> str | tuple of str")]
        if not isinstance(value, ast.Dict):
            return None, bad, line
        mapping: dict[str, tuple[str, ...]] = {}
        for k, v in zip(value.keys, value.values):
            if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                return None, bad, line
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                mapping[k.value] = (v.value,)
            elif isinstance(v, (ast.Tuple, ast.List)) and all(
                    isinstance(e, ast.Constant) and isinstance(e.value, str)
                    for e in v.elts):
                mapping[k.value] = tuple(e.value for e in v.elts)
            else:
                return None, bad, line
        return mapping, [], line
    return None, [], 0


def _with_locks(item: ast.withitem, lock_names: frozenset) -> str | None:
    """Lock attribute acquired by one with-item: ``self._lock`` or
    ``self._locks[key]`` (a dict of locks counts as one named lock)."""
    expr = item.context_expr
    if isinstance(expr, ast.Subscript):
        expr = expr.value
    if (isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"
            and expr.attr in lock_names):
        return expr.attr
    return None


def _scan_function(fn, method_names: set, lock_names: frozenset,
                   guarded: dict, nested_out: list,
                   nested: bool = False) -> _MethodScan:
    scan = _MethodScan(name=fn.name, public=not fn.name.startswith("_"),
                       nested=nested)

    def visit(node: ast.AST, held: frozenset) -> None:
        if isinstance(node, ast.With):
            for item in node.items:
                lk = _with_locks(item, lock_names)
                if lk is not None:
                    held = held | {lk}
                visit(item.context_expr, held)
                if item.optional_vars is not None:
                    visit(item.optional_vars, held)
            for stmt in node.body:
                visit(stmt, held)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a def nested in a method body: runs later, possibly on
            # another thread, with no lock held — scan it as its own
            # zero-guarantee entry point
            nested_out.append(_scan_function(
                node, method_names, lock_names, guarded, nested_out,
                nested=True))
            return
        if isinstance(node, ast.Call):
            func = node.func
            if (isinstance(func, ast.Attribute)
                    and isinstance(func.value, ast.Name)
                    and func.value.id == "self"
                    and func.attr in method_names):
                scan.calls.append(_CallSite(callee=func.attr, held=held))
                # do not record the self.<m> attribute itself as an access
                for arg in node.args:
                    visit(arg, held)
                for kw in node.keywords:
                    visit(kw.value, held)
                return
        if isinstance(node, ast.Attribute):
            if (isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                if node.attr in guarded:
                    scan.accesses.append(
                        _Access(attr=node.attr, line=node.lineno, held=held))
                elif node.attr in method_names:
                    scan.callbacks.add(node.attr)
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for stmt in fn.body:
        visit(stmt, frozenset())
    return scan


def _check_class(path: str, cls: ast.ClassDef) -> list[Finding]:
    guarded, bad, decl_line = _parse_guarded_by(cls)
    if bad:
        return [dataclasses.replace(f, path=path) for f in bad]
    if guarded is None:
        return []
    lock_names = frozenset(lk for locks in guarded.values() for lk in locks)
    all_locks = lock_names

    methods = {stmt.name: stmt for stmt in cls.body
               if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))}
    method_names = set(methods)
    nested: list[_MethodScan] = []
    scans = {name: _scan_function(fn, method_names, lock_names, guarded,
                                  nested)
             for name, fn in methods.items()}
    for extra in nested:
        # nested defs can shadow; key them uniquely but keep the name for
        # callback detection (they are entries regardless)
        scans[f"{extra.name}@{id(extra)}"] = extra

    callbacks = set()
    for scan in scans.values():
        callbacks |= scan.callbacks

    called_by: dict[str, list[tuple[str, frozenset]]] = {}
    for mname, scan in scans.items():
        for site in scan.calls:
            called_by.setdefault(site.callee, []).append((mname, site.held))

    # exemption fixpoint: __init__/__post_init__ plus private, non-callback
    # helpers whose every call site sits in an exempt method
    exempt = {m for m in scans if m.split("@")[0] in EXEMPT_METHODS}
    changed = True
    while changed:
        changed = False
        for mname, scan in scans.items():
            if mname in exempt or scan.public or scan.nested:
                continue
            if scan.name in callbacks:
                continue
            sites = called_by.get(mname, [])
            if sites and all(caller in exempt for caller, _ in sites):
                if mname not in exempt:
                    exempt.add(mname)
                    changed = True

    def is_entry(mname: str, scan: _MethodScan) -> bool:
        if mname in exempt:
            return False
        if scan.public or scan.nested or scan.name in callbacks:
            return True
        if scan.name.startswith("__") and scan.name.endswith("__"):
            return True                      # dunders: external callers
        return not called_by.get(mname)      # private and never called

    # guarantee fixpoint: locks surely held whenever a method runs
    guaranteed: dict[str, frozenset] = {}
    for mname, scan in scans.items():
        if mname in exempt:
            guaranteed[mname] = all_locks
        elif is_entry(mname, scan):
            guaranteed[mname] = frozenset()
        else:
            guaranteed[mname] = all_locks
    changed = True
    while changed:
        changed = False
        for mname, scan in scans.items():
            if mname in exempt or is_entry(mname, scan):
                continue
            avail = all_locks
            for caller, held in called_by.get(mname, []):
                avail = avail & (held | guaranteed[caller])
            if avail != guaranteed[mname]:
                guaranteed[mname] = avail
                changed = True

    findings: list[Finding] = []
    used_attrs = set()
    for mname, scan in scans.items():
        for acc in scan.accesses:
            used_attrs.add(acc.attr)
            if mname in exempt:
                continue
            ok = set(guarded[acc.attr]) & (acc.held | guaranteed[mname])
            if not ok:
                locks = " | ".join(guarded[acc.attr])
                findings.append(Finding(
                    path, acc.line, "GH101",
                    f"{cls.name}.{scan.name} touches self.{acc.attr} "
                    f"without holding {locks}"))
    for attr in guarded:
        if attr not in used_attrs:
            findings.append(Finding(
                path, decl_line, "GH102",
                f"_guarded_by declares {attr!r} but {cls.name} never "
                f"accesses self.{attr}"))
    return findings


def check_file(path: str, text: str, tree: ast.AST) -> list[Finding]:
    """Run the lock checker over one parsed module."""
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_check_class(path, node))
    return findings
