"""repro-lint: invariant-enforcing static analyzers (stdlib ``ast`` only).

Five checkers, one per invariant family the repo's correctness story
leans on (DESIGN.md §15):

  locks        GH1xx  lock discipline for ``_guarded_by``-declared state
  determinism  GH2xx  cross-rank bit-identity hazards in merge/plan code
  atomicity    GH3xx  staged tmp-write -> fsync -> os.replace protocol
  shapes       GH4xx  the ``[V,Q]`` docstring shape grammar
  docstrings   GH5xx  public APIs must carry a docstring

Run them through ``tools/analyze.py``; suppress individual findings with
``# lint: allow(CODE): justification`` (the justification is mandatory).
"""
from __future__ import annotations

from . import atomicity, determinism, docstrings, locks, shapes

#: name -> checker module; each module exposes ``CODES`` (code -> one-line
#: description), ``applies(relpath)`` and ``check_file(path, text, tree)``.
CHECKERS = {
    "locks": locks,
    "determinism": determinism,
    "atomicity": atomicity,
    "shapes": shapes,
    "docstrings": docstrings,
}
