"""GH5xx — public-API docstring checker (pydocstyle-lite).

The fifth checker: every public module, class, function, and method in
the enforced packages must carry a docstring — that is where the repo
documents array shapes (``[V, Q]``), units (bytes vs elements), and
thread-safety (docs/ARCHITECTURE.md).  Ported from the original
``tools/check_docstrings.py`` (which now delegates here) and widened
from ``core/`` + ``graphio/`` to ``kernels/`` and ``serve/`` as well.

  GH501  public API without a docstring

Private names, nested defs, and methods of private classes are
implementation detail and are not checked.
"""
from __future__ import annotations

import ast

from .common import Finding, is_public, suffix_match

CODES = {
    "GH501": "public API without a docstring",
}

TARGET_SUFFIXES = (
    "src/repro/core/",
    "src/repro/graphio/",
    "src/repro/kernels/",
    "src/repro/serve/",
)


def applies(relpath: str) -> bool:
    return suffix_match(relpath, TARGET_SUFFIXES)


def check_file(path: str, text: str, tree: ast.AST) -> list[Finding]:
    """Run the docstring checker over one parsed module."""
    findings: list[Finding] = []
    if ast.get_docstring(tree) is None:
        findings.append(Finding(path, 1, "GH501", "module docstring missing"))

    def walk(node: ast.AST, scope: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                name = child.name
                qual = f"{scope}{name}"
                is_cls = isinstance(child, ast.ClassDef)
                if is_public(name) and ast.get_docstring(child) is None:
                    kind = "class" if is_cls else "def"
                    findings.append(Finding(
                        path, child.lineno, "GH501",
                        f"{kind} {qual} has no docstring (document shapes/"
                        f"units/thread-safety — docs/ARCHITECTURE.md)"))
                # descend into PUBLIC classes for their methods — private
                # classes and function bodies are implementation detail
                if is_cls and is_public(name):
                    walk(child, f"{qual}.")

    walk(tree, "")
    return findings
