#!/usr/bin/env python
"""repro-lint: the invariant-enforcing static-analysis suite.

Usage:
    python tools/analyze.py [--check NAME]... [--all-files] [paths...]

Runs the ``tools/analyzers/`` checkers (stdlib ``ast`` only — the
container has no third-party linters) over the given files/directories
(default ``src/repro``) and prints machine-readable findings, one per
line::

    src/repro/core/cache.py:321 GH101 EdgeCache.maintain touches ...

Checkers (``--check`` may repeat; default is all):
  locks         GH1xx  _guarded_by lock-discipline race checker
  determinism   GH2xx  cross-rank determinism lint
  atomicity     GH3xx  staged-write (tmp -> fsync -> os.replace) checker
  shapes        GH4xx  docstring shape-contract checker
  docstrings    GH5xx  public-API docstring checker

Findings are suppressed inline with a justified allow comment on the
finding's line or the line directly above::

    # lint: allow(GH205): inbox dict is filled in rank order at __init__

An allow with no justification is itself a finding (GH001); when every
checker runs, an allow that matches nothing is too (GH002) so stale
suppressions cannot accumulate.  Exit code 1 on any finding.

Each checker limits itself to the modules where its invariant is
load-bearing (``TARGET_SUFFIXES``); ``--all-files`` disables that
filter — used by the fixture tests to lint files outside ``src/repro``.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from analyzers import CHECKERS                      # noqa: E402
from analyzers.common import (Finding, Suppressions,  # noqa: E402
                              iter_py_files, load_source, norm_relpath)


def run(paths: list[str], checks: list[str],
        all_files: bool = False) -> tuple[list[Finding], int]:
    """Run the named checkers over ``paths``.

    Returns ``(findings, suppressed_count)`` — findings sorted by
    ``(path, line, code)`` and already filtered through the inline
    suppressions, with GH001/GH002 suppression-hygiene findings
    appended.  GH002 (unused allow) is only meaningful when every
    checker ran: a subset run legitimately leaves other checkers'
    allows unmatched.
    """
    report_unused = set(checks) == set(CHECKERS)
    findings: list[Finding] = []
    total_suppressed = 0
    for path in iter_py_files(paths):
        rel = norm_relpath(path)
        try:
            text, tree = load_source(path)
        except SyntaxError as exc:
            findings.append(Finding(path, exc.lineno or 1, "GH000",
                                    f"syntax error: {exc.msg}"))
            continue
        supp = Suppressions(path, text)
        raw: list[Finding] = []
        for name in checks:
            mod = CHECKERS[name]
            if all_files or mod.applies(rel):
                raw.extend(mod.check_file(path, text, tree))
        kept, n_supp = supp.filter(raw)
        total_suppressed += n_supp
        findings.extend(kept)
        findings.extend(supp.problems(report_unused=report_unused))
    return sorted(findings), total_suppressed


def main(argv: list[str]) -> int:
    """CLI entry point; prints findings and a summary, exits 1 on any."""
    parser = argparse.ArgumentParser(
        prog="analyze.py",
        description="repro-lint invariant checkers (see module docstring)")
    parser.add_argument("paths", nargs="*", default=["src/repro"],
                        help="files or directories (default: src/repro)")
    parser.add_argument("--check", action="append", choices=sorted(CHECKERS),
                        help="run only this checker (repeatable)")
    parser.add_argument("--all-files", action="store_true",
                        help="ignore per-checker TARGET_SUFFIXES filters")
    args = parser.parse_args(argv)

    checks = args.check or sorted(CHECKERS)
    findings, suppressed = run(args.paths, checks, all_files=args.all_files)

    for f in findings:
        print(f.render())
    summary = (f"repro-lint: {len(findings)} finding(s), "
               f"{suppressed} justified suppression(s) "
               f"[checks: {', '.join(checks)}]")
    print(("\n" if findings else "") + summary,
          file=sys.stderr if findings else sys.stdout)

    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a", encoding="utf-8") as fh:
            fh.write(f"### repro-lint\n\n{summary}\n\n")
            for f in findings:
                fh.write(f"- `{f.render()}`\n")

    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
