"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

ROWS = []

# --smoke (benchmarks/run.py): shrink problem sizes so every bench path is
# exercisable in CI on every push without meaningful runtime.
SMOKE = False


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timer():
    return time.perf_counter()


_STORE_CACHE = {}


def make_store(nv: int, ne: int, tile_size: int, weighted=False, seed=0,
               disk_mode=1, graph="rmat", num_intervals=0):
    """Build (and memoize) a synthetic tile store (default: RMAT)."""
    from repro.graphio import spe, synth
    from repro.graphio.formats import TileStore

    key = (nv, ne, tile_size, weighted, seed, disk_mode, graph, num_intervals)
    if key in _STORE_CACHE:
        return _STORE_CACHE[key]
    gen = {"rmat": synth.rmat_edges, "uniform": synth.uniform_edges,
           "banded": synth.banded_edges}[graph]
    root = tempfile.mkdtemp(prefix="bench_store_")
    store = TileStore(root, disk_mode=disk_mode)
    spe.preprocess(
        lambda: gen(nv, ne, seed=seed, weighted=weighted),
        nv, store, tile_size=tile_size, weighted=weighted,
        num_intervals=num_intervals)
    _STORE_CACHE[key] = store
    return store


def rmat_arrays(nv, ne, seed=0, weighted=False):
    from repro.graphio import synth

    srcs, dsts, vals = [], [], []
    for s, d, v in synth.rmat_edges(nv, ne, seed=seed, weighted=weighted):
        srcs.append(s)
        dsts.append(d)
        if v is not None:
            vals.append(v)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    val = np.concatenate(vals) if vals else None
    return src, dst, val
