# HTTP serving benchmark (DESIGN.md §16; beyond the GraphH paper,
# which is batch-only).
#
#   PYTHONPATH=src python -m benchmarks.run --only serve_http [--smoke]
#
# Drives the stdlib HTTP frontend (serve/http.py) over a real TCP socket
# with threaded urllib clients:
#
#   latency sweep — mixed PPR + MS-BFS offered at each QPS (0 = closed
#       loop); reports CLIENT-observed p50/p99 submit-to-result latency
#       (includes HTTP + polling overhead) next to the server's own
#       queue/service split, plus result-cache hit counts;
#   fairness drill — two tenants at 3:1 weights with a 10:1 offered-load
#       skew against the high-weight tenant; reports the deficit-round-
#       robin fairness ratio (observed high-weight admission share over
#       the contended windows / weight-proportional ideal; 1.0 = exact).
#
# Results land in bench_serve_http.json (override with
# BENCH_SERVE_HTTP_OUT) so CI uploads the sweep as an artifact.
from __future__ import annotations

import json
import os
import threading
import time
import urllib.request

import numpy as np

from benchmarks import common
from benchmarks.common import emit, make_store


def _out_path() -> str:
    return os.environ.get("BENCH_SERVE_HTTP_OUT", "bench_serve_http.json")


def _save(key: str, payload) -> None:
    path = _out_path()
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[key] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def _post(base: str, body: dict) -> dict:
    req = urllib.request.Request(
        base + "/v1/query", data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=60) as r:
        return json.loads(r.read())


def _poll(base: str, rid: int, timeout: float = 600.0) -> dict:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        with urllib.request.urlopen(base + f"/v1/query/{rid}",
                                    timeout=60) as r:
            j = json.loads(r.read())
        if j["status"] in ("done", "timeout", "failed"):
            return j
        time.sleep(0.01)
    raise AssertionError(f"rid {rid} never finished")


def _serve(store, **kw):
    from repro.core.engine import EngineConfig
    from repro.serve.graph_service import GraphService
    from repro.serve.http import HttpFrontend

    cfg = EngineConfig(num_servers=2, max_supersteps=200)
    svc = GraphService(store, cfg, min_fill=1, max_wait_s=0.01,
                       max_supersteps=200, **kw)
    fe = HttpFrontend(svc).start()
    return svc, fe


def _drive_http(store, nv, *, qps, requests, seed=0):
    svc, fe = _serve(store, q_slots=4, result_cache=64)
    svc.start()
    base = fe.address
    rng = np.random.default_rng(seed)
    apps = ("ppr", "msbfs")
    lat = [None] * requests

    def client(i, app, s):
        t0 = time.perf_counter()
        t = _post(base, dict(app=app, seed=s, tenant=f"t{i % 2}"))
        _poll(base, t["rid"])
        lat[i] = time.perf_counter() - t0

    t0 = time.perf_counter()
    threads = []
    for i in range(requests):
        if qps > 0 and i:
            time.sleep(1.0 / qps)
        th = threading.Thread(target=client,
                              args=(i, apps[i % len(apps)],
                                    int(rng.integers(nv))))
        th.start()
        threads.append(th)
    for th in threads:
        th.join(600)
    wall = time.perf_counter() - t0
    assert all(v is not None for v in lat)
    snap = svc.stats_snapshot()
    server = svc.latency_summary()
    http = fe.counters()
    svc.request_drain()
    svc.join(600)
    fe.close()
    tot = np.asarray(lat)
    return dict(
        offered_qps=qps,
        requests=requests,
        wall_seconds=wall,
        queries_per_sec=requests / wall,
        client_p50_ms=float(np.percentile(tot, 50) * 1e3),
        client_p99_ms=float(np.percentile(tot, 99) * 1e3),
        server_p50_ms=server.get("p50_ms", 0.0),
        server_p99_ms=server.get("p99_ms", 0.0),
        mean_queue_ms=server.get("mean_queue_ms", 0.0),
        mean_service_ms=server.get("mean_service_ms", 0.0),
        cache_hits=snap["stats"]["cache_hits"],
        http_requests=http["requests"],
    )


def _fairness_drill(store, nv, *, gold_n, free_n, q_slots=4):
    """10:1 offered-load skew against the weight-3 tenant: queue the
    whole skewed backlog over HTTP before the serve loop starts, then
    audit the admission order against the weight-proportional ideal."""
    svc, fe = _serve(store, q_slots=q_slots,
                     tenants={"gold": 3.0, "free": 1.0})
    base = fe.address
    rng = np.random.default_rng(1)
    rids = []
    for tenant, n in (("gold", gold_n), ("free", free_n)):
        for _ in range(n):
            t = _post(base, dict(app="msbfs", seed=int(rng.integers(nv)),
                                 tenant=tenant))
            rids.append(t["rid"])
    svc.start()
    for rid in rids:
        assert _poll(base, rid)["status"] == "done"
    # contended windows: while gold stays backlogged it should land 3 of
    # every q_slots admissions (weights 3:1)
    tickets = sorted((svc.get(rid) for rid in rids),
                     key=lambda t: t.admitted_s)
    windows = gold_n // 3
    head = tickets[: windows * q_slots]
    gold_seen = sum(t.tenant == "gold" for t in head)
    ratio = gold_seen / (3 * windows)
    ts = svc.stats_snapshot()["tenants"]
    svc.request_drain()
    svc.join(600)
    fe.close()
    assert ts["gold"]["done"] == gold_n and ts["free"]["done"] == free_n
    return dict(
        gold_offered=gold_n,
        free_offered=free_n,
        q_slots=q_slots,
        contended_windows=windows,
        gold_admitted_in_windows=gold_seen,
        fairness_ratio=ratio,
        tenants=ts,
    )


def bench_serve_http():
    smoke = common.SMOKE
    nv, ne = (1_500, 9_000) if smoke else (8_000, 80_000)
    requests = 6 if smoke else 24
    qps_sweep = (0.0, 8.0) if smoke else (0.0, 2.0, 8.0)
    store = make_store(nv, ne, tile_size=1024 if smoke else 4096)
    rows = []
    for qps in qps_sweep:
        r = _drive_http(store, nv, qps=qps, requests=requests)
        rows.append(r)
        emit(f"serve_http_qps{qps:g}", r["client_p50_ms"] * 1e3,
             f"p99={r['client_p99_ms']:.0f}ms "
             f"qps={r['queries_per_sec']:.2f} "
             f"server_p50={r['server_p50_ms']:.0f}ms "
             f"hits={r['cache_hits']}")
    _save("latency", rows)
    fair = _fairness_drill(store, nv, gold_n=3 if smoke else 9,
                           free_n=30 if smoke else 90)
    # DRR acceptance: within one query per contended window of the
    # weight-proportional share
    slack = 1.0 / (3 * fair["contended_windows"])
    assert abs(fair["fairness_ratio"] - 1.0) <= slack + 1e-9, fair
    emit("serve_http_fairness", fair["fairness_ratio"] * 1e6,
         f"gold {fair['gold_admitted_in_windows']}/"
         f"{3 * fair['contended_windows']} of contended admissions "
         f"under 10:1 skew")
    _save("fairness", fair)


ALL = [bench_serve_http]
