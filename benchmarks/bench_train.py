"""LM substrate throughput on CPU (reduced configs) — tokens/s for the
train step and the serve engine, plus checkpoint save/restore latency."""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def bench_train_step():
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.train import data as datalib
    from repro.train import train_step as ts
    from repro.train.optimizer import OptConfig

    run = RunConfig(remat="none", q_chunk=32, kv_chunk=32, loss_chunk=32,
                    compute_dtype="float32")
    for arch in ("qwen3-1.7b", "granite-moe-1b-a400m", "rwkv6-1.6b"):
        cfg = registry.get_config(arch, reduced=True)
        step, init, _ = ts.build_train_step(cfg, run, OptConfig())
        state = init(jax.random.key(0))
        src = datalib.SyntheticLM(cfg, 8, 64)
        b = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
        state, _ = step(state, b)                      # compile
        t0 = time.perf_counter()
        for i in range(1, 6):
            b = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
            state, stats = step(state, b)
        jax.block_until_ready(stats["loss"])
        dt = (time.perf_counter() - t0) / 5
        emit(f"train.step.{arch}", dt * 1e6,
             f"tok_per_s={8*64/dt:,.0f}")


def bench_serve_engine():
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.models.model_zoo import build_model
    from repro.serve.engine import Request, ServeEngine

    run = RunConfig(remat="none", q_chunk=32, kv_chunk=32,
                    compute_dtype="float32")
    cfg = registry.get_config("qwen3-1.7b", reduced=True)
    params = build_model(cfg, run).init(jax.random.key(0))
    rng = np.random.default_rng(0)
    for slots in (1, 4):
        eng = ServeEngine(cfg, run, params, slots=slots, max_len=128)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32), max_new_tokens=16)
                for i in range(8)]
        t0 = time.perf_counter()
        outs = eng.run_requests(reqs)
        dt = time.perf_counter() - t0
        tok = sum(len(o.tokens) for o in outs)
        emit(f"serve.engine.slots{slots}", dt / max(tok, 1) * 1e6,
             f"tok_per_s={tok/dt:.1f} decode_steps={eng.stats['decode_steps']}")


def bench_checkpoint():
    from repro.train.checkpoint import CheckpointManager

    state = {"params": {f"w{i}": jnp.zeros((256, 256)) for i in range(8)}}
    mgr = CheckpointManager(tempfile.mkdtemp())
    t0 = time.perf_counter()
    mgr.save(1, state)
    ts_ = time.perf_counter() - t0
    t0 = time.perf_counter()
    mgr.restore(1)
    tr = time.perf_counter() - t0
    mb = 8 * 256 * 256 * 4 / 1e6
    emit("ckpt.save", ts_ * 1e6, f"MBps={mb/ts_:.0f}")
    emit("ckpt.restore", tr * 1e6, f"MBps={mb/tr:.0f}")


ALL = [bench_train_step, bench_serve_engine, bench_checkpoint]
