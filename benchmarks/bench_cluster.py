# Cluster-runtime benchmarks (paper §IV cluster evaluation; DESIGN.md §11).
#
#   PYTHONPATH=src python -m benchmarks.run --only cluster [--smoke]
#
# Two sweeps over REAL multi-process cluster runs (launch.cluster):
#
#   bench_cluster_comm  — wire bytes per superstep for dense vs sparse vs
#       hybrid broadcast on a zipf-skewed (R-MAT) and a banded graph at
#       N=2 servers.  The hybrid encoder ships the smallest measured
#       candidate per server per superstep, so its per-superstep total
#       must be <= min(dense, sparse) — asserted here, recorded in the
#       JSON artifact.
#   bench_cluster_scaling — superstep wall time + wire bytes at
#       N in {1, 2, 4} servers (hybrid), same graph.
#
# Results land in bench_cluster.json (override with BENCH_CLUSTER_OUT) so
# CI can upload the sweep as an artifact.
from __future__ import annotations

import json
import os
import time

from benchmarks import common
from benchmarks.common import emit, make_store


def _out_path() -> str:
    return os.environ.get("BENCH_CLUSTER_OUT", "bench_cluster.json")


def _save(key: str, payload: dict) -> None:
    path = _out_path()
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[key] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def _run(store, app, n, comm_mode, supersteps, steal=False):
    from repro.core.engine import EngineConfig
    from repro.launch.cluster import ClusterConfig, run_cluster

    cfg = ClusterConfig(
        num_servers=n, steal=steal,
        engine=EngineConfig(comm_mode=comm_mode, max_supersteps=supersteps))
    t0 = time.perf_counter()
    out = run_cluster(store.root, [app], cfg)
    dt = time.perf_counter() - t0
    assert out.verified, "cluster ranks diverged"
    res = out.results[0]
    return dict(
        seconds=dt,
        supersteps=res.supersteps,
        wire_per_superstep=[h.wire_bytes for h in res.history],
        network_bytes=sum(h.network_bytes for h in res.history),
        mean_superstep_seconds=res.mean_superstep_seconds(),
    )


def bench_cluster_comm():
    from repro.core.apps import PageRank, SSSP

    smoke = common.SMOKE
    nv, ne = (300, 2000) if smoke else (20_000, 200_000)
    ss = 6 if smoke else 12
    tile = 128 if smoke else 8192
    jobs = [
        # zipf-skewed degrees: dense frontiers early, long sparse tail
        ("zipf", make_store(nv, ne, tile, graph="rmat"), PageRank()),
        # banded locality: narrow frontiers, sparse wins most supersteps
        ("banded", make_store(nv, ne, tile, graph="banded", weighted=True),
         SSSP(source=0)),
    ]
    for gname, store, app in jobs:
        rows = {}
        for mode in ("dense", "sparse", "hybrid"):
            rows[mode] = _run(store, app, n=2, comm_mode=mode, supersteps=ss)
            emit(f"cluster_comm_{gname}_{mode}",
                 rows[mode]["mean_superstep_seconds"] * 1e6,
                 f"wire={sum(rows[mode]['wire_per_superstep'])}B/"
                 f"{rows[mode]['supersteps']}ss")
        # hybrid ships the smallest measured candidate per server per
        # superstep -> never above the best pure mode, per superstep
        n_ss = min(len(rows[m]["wire_per_superstep"]) for m in rows)
        for i in range(n_ss):
            hyb = rows["hybrid"]["wire_per_superstep"][i]
            lo = min(rows["dense"]["wire_per_superstep"][i],
                     rows["sparse"]["wire_per_superstep"][i])
            assert hyb <= lo, (gname, i, hyb, lo)
        _save(f"comm_{gname}", rows)
        emit(f"cluster_comm_{gname}_check", 0.0,
             "hybrid<=min(dense;sparse) per superstep: PASS")


def bench_cluster_scaling():
    from repro.core.apps import PageRank

    smoke = common.SMOKE
    nv, ne = (300, 2000) if smoke else (20_000, 200_000)
    ss = 6 if smoke else 12
    tile = 128 if smoke else 8192
    store = make_store(nv, ne, tile, graph="rmat")
    servers = (1, 2) if smoke else (1, 2, 4)
    rows = {}
    for n in servers:
        rows[str(n)] = _run(store, PageRank(), n=n, comm_mode="hybrid",
                            supersteps=ss)
        emit(f"cluster_scaling_n{n}",
             rows[str(n)]["mean_superstep_seconds"] * 1e6,
             f"net={rows[str(n)]['network_bytes']}B")
    _save("scaling", rows)


ALL = [bench_cluster_comm, bench_cluster_scaling]
