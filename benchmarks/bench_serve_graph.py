# Online graph-query serving benchmark (DESIGN.md §13; beyond the
# GraphH paper, which is batch-only).
#
#   PYTHONPATH=src python -m benchmarks.run --only serve_graph [--smoke]
#
# Drives serve.graph_service with a mixed PPR + MS-BFS workload two ways
# per q_slots setting:
#
#   closed-loop (qps=0) — every query offered upfront; measures the
#       service's saturated throughput (queries/sec) and the latency
#       cost of queueing behind a full slot set;
#   open-loop — queries arrive at an offered QPS; measures p50/p99
#       submit-to-result latency when slots usually have headroom.
#
# Reported per (q_slots, offered qps): p50/p99 total latency, mean queue
# vs service split, supersteps/query, and achieved queries/sec.  Results
# land in bench_serve_graph.json (override with BENCH_SERVE_GRAPH_OUT)
# so CI uploads the sweep as an artifact.
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit, make_store


def _out_path() -> str:
    return os.environ.get("BENCH_SERVE_GRAPH_OUT", "bench_serve_graph.json")


def _save(key: str, payload) -> None:
    path = _out_path()
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[key] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def _drive(store, nv, *, q_slots, qps, requests, seed=0):
    from repro.core.engine import EngineConfig
    from repro.serve.graph_service import GraphService

    cfg = EngineConfig(num_servers=2, max_supersteps=200)
    svc = GraphService(store, cfg, q_slots=q_slots, min_fill=1,
                       max_wait_s=0.01, max_supersteps=200)
    svc.start()
    rng = np.random.default_rng(seed)
    apps = ("ppr", "msbfs")
    t0 = time.perf_counter()
    tickets = []
    for i in range(requests):
        if qps > 0 and i:
            time.sleep(1.0 / qps)
        tickets.append(svc.submit(apps[i % len(apps)],
                                  int(rng.integers(nv))))
    for t in tickets:
        assert t.wait(600), t
    wall = time.perf_counter() - t0
    svc.request_drain()
    svc.join(600)
    s = svc.latency_summary()
    assert s["count"] == requests and s["timeouts"] == 0
    return dict(
        q_slots=q_slots,
        offered_qps=qps,
        requests=requests,
        wall_seconds=wall,
        queries_per_sec=requests / wall,
        p50_ms=s["p50_ms"],
        p99_ms=s["p99_ms"],
        mean_queue_ms=s["mean_queue_ms"],
        mean_service_ms=s["mean_service_ms"],
        mean_supersteps=s["mean_supersteps"],
        supersteps_total=svc.stats["supersteps"],
        sessions=svc.stats["sessions_opened"],
    )


def bench_serve_graph():
    smoke = common.SMOKE
    nv, ne = (1_500, 9_000) if smoke else (8_000, 80_000)
    requests = 6 if smoke else 24
    slot_sweep = (2, 4) if smoke else (2, 8)
    qps_sweep = (0.0, 8.0) if smoke else (0.0, 2.0, 8.0)
    store = make_store(nv, ne, tile_size=1024 if smoke else 4096)
    rows = []
    for q in slot_sweep:
        for qps in qps_sweep:
            r = _drive(store, nv, q_slots=q, qps=qps, requests=requests)
            rows.append(r)
            emit(f"serve_graph_q{q}_qps{qps:g}", r["p50_ms"] * 1e3,
                 f"p99={r['p99_ms']:.0f}ms "
                 f"qps={r['queries_per_sec']:.2f} "
                 f"queue={r['mean_queue_ms']:.0f}ms "
                 f"ss/q={r['mean_supersteps']:.1f}")
    # more slots must not lose throughput closed-loop (shared tile
    # visits amortize across more live columns)
    closed = {r["q_slots"]: r for r in rows if r["offered_qps"] == 0}
    lo, hi = min(closed), max(closed)
    emit("serve_graph_slot_speedup",
         closed[hi]["wall_seconds"] * 1e6,
         f"q{hi} vs q{lo} closed-loop: "
         f"{closed[hi]['queries_per_sec'] / closed[lo]['queries_per_sec']:.2f}x qps")
    _save("latency", rows)


ALL = [bench_serve_graph]
