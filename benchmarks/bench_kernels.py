"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle vs numpy.

On this CPU container interpret-mode timing only proves correctness-path
cost; the derived column reports achieved GB/s for the oracle (the XLA-
compiled path) which is the deployable CPU number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_segment_sum():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    for E, R in [(1 << 16, 4096), (1 << 20, 32768)]:
        c = jnp.asarray(rng.normal(size=E).astype(np.float32))
        d = jnp.asarray(np.sort(rng.integers(0, R, E)).astype(np.int32))
        t_ref = _time(lambda a, b: ref.segment_sum(a, b, R), c, d)
        gbps = E * 8 / t_ref / 1e9
        emit(f"kern.segsum.ref.E{E}", t_ref * 1e6, f"GBps={gbps:.2f}")
        if E <= 1 << 16:   # interpret mode is slow; validate small only
            t_pal = _time(lambda a, b: ops.segment_sum(a, b, R), c, d)
            emit(f"kern.segsum.pallas_interp.E{E}", t_pal * 1e6,
                 "interpret=True (correctness path)")


def bench_compact():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    n = 1 << 18
    mask = jnp.asarray(rng.random(n) < 0.2)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    K = int(0.4 * n)
    t_ref = _time(lambda m, v: ref.compact(m, v, K), mask, vals)
    emit(f"kern.compact.ref.n{n}", t_ref * 1e6,
         f"GBps={n*5/t_ref/1e9:.2f}")


def bench_gab_superstep():
    """Engine-level throughput: edges/s for one PageRank superstep."""
    from benchmarks.common import make_store
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    nv, ne = 100_000, 1_000_000
    store = make_store(nv, ne, 65536)
    eng = OutOfCoreEngine(store, EngineConfig(num_servers=1,
                                              max_supersteps=5))
    res = eng.run(PageRank())
    sec = res.mean_superstep_seconds()
    emit("kern.gab.superstep.1M_edges", sec * 1e6,
         f"Medges_per_s={ne/sec/1e6:.1f}")


ALL = [bench_segment_sum, bench_compact, bench_gab_superstep]
