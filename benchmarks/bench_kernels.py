"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle vs numpy.

On this CPU container interpret-mode timing only proves correctness-path
cost; the derived column reports achieved GB/s for the oracle (the XLA-
compiled path) which is the deployable CPU number.

``bench_kernel_fused`` sweeps fused-kernel block sizes per app-monoid and
validates the roofline autotuner's pick against a measured grid search;
rows land in ``BENCH_kernels.json`` (override via ``BENCH_KERNELS_OUT``)
for the perf-trajectory artifacts.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_segment_sum():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    for E, R in [(1 << 16, 4096), (1 << 20, 32768)]:
        c = jnp.asarray(rng.normal(size=E).astype(np.float32))
        d = jnp.asarray(np.sort(rng.integers(0, R, E)).astype(np.int32))
        t_ref = _time(lambda a, b: ref.segment_sum(a, b, R), c, d)
        gbps = E * 8 / t_ref / 1e9
        emit(f"kern.segsum.ref.E{E}", t_ref * 1e6, f"GBps={gbps:.2f}")
        if E <= 1 << 16:   # interpret mode is slow; validate small only
            t_pal = _time(lambda a, b: ops.segment_sum(a, b, R), c, d)
            emit(f"kern.segsum.pallas_interp.E{E}", t_pal * 1e6,
                 "interpret=True (correctness path)")


def bench_compact():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    n = 1 << 18
    mask = jnp.asarray(rng.random(n) < 0.2)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    K = int(0.4 * n)
    t_ref = _time(lambda m, v: ref.compact(m, v, K), mask, vals)
    emit(f"kern.compact.ref.n{n}", t_ref * 1e6,
         f"GBps={n*5/t_ref/1e9:.2f}")


def bench_gab_superstep():
    """Engine-level throughput: edges/s for one PageRank superstep."""
    from benchmarks.common import make_store
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    nv, ne = 100_000, 1_000_000
    store = make_store(nv, ne, 65536)
    eng = OutOfCoreEngine(store, EngineConfig(num_servers=1,
                                              max_supersteps=5))
    res = eng.run(PageRank())
    sec = res.mean_superstep_seconds()
    emit("kern.gab.superstep.1M_edges", sec * 1e6,
         f"Medges_per_s={ne/sec/1e6:.1f}")


def _kernels_out_path() -> str:
    return os.environ.get("BENCH_KERNELS_OUT", "BENCH_kernels.json")


def _save_kernels(key: str, payload) -> None:
    path = _kernels_out_path()
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[key] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def bench_kernel_fused():
    """Fused GAB kernel block sweep: roofline-model pick vs grid search.

    Per app-monoid the sweep measures the fused kernel at a grid of
    (BE, BR) candidates plus the historical static (512, 256) and the
    autotuner's pick, then reports the model's edges/sec ceiling and the
    measured gap to that roofline.  Asserts the pick's measured time does
    not lose to the static default beyond timing noise — the acceptance
    gate for EngineConfig.kernel_autotune's default candidacy.

    The measured gate only applies on a TPU backend: interpret-mode
    emulation cost scales with padded block *area*, so on CPU the grid
    search rewards tiny blocks that a real TPU would spend all its time
    dispatching.  Everywhere the bench still enforces the deterministic
    model-side relation (pick never predicts worse than static) and
    records the full measured grid so the inversion is visible in the
    artifact rather than papered over.
    """
    import jax
    from repro.kernels.gab_fused import FusedSpec, gab_fused
    from repro.roofline import kernel_tune

    smoke = common.SMOKE
    edge_cap, row_cap = (2048, 256) if smoke else (16384, 1024)
    noise_tol = 1.6 if smoke else 1.25
    apps = [
        ("pagerank", 1, FusedSpec(combine="sum", scale_aux="inv",
                                  apply="affine", alpha=0.15, beta=0.85,
                                  update_tol=1e-8)),
        ("sssp", 1, FusedSpec(combine="min", add_edge=True, apply="min")),
        ("msbfs", 8, FusedSpec(combine="min", add_const=1.0, apply="min")),
    ]
    rng = np.random.default_rng(0)
    results = {}
    for app, q, spec in apps:
        shape = (edge_cap,) if q == 1 else (edge_cap, q)
        sv = jnp.asarray(np.abs(rng.normal(size=shape)).astype(np.float32))
        a = (jnp.asarray(rng.random(edge_cap).astype(np.float32))
             if spec.scale_aux else None)
        b = (jnp.asarray(rng.random(edge_cap).astype(np.float32))
             if spec.add_edge else None)
        dst = jnp.asarray(np.sort(
            rng.integers(0, row_cap, edge_cap)).astype(np.int32))
        oshape = (row_cap,) if q == 1 else (row_cap, q)
        old = jnp.asarray(
            np.abs(rng.normal(size=oshape)).astype(np.float32) + 1.0)
        nr = jnp.int32(row_cap)

        choice = kernel_tune.pick_blocks(spec.combine, q, edge_cap, row_cap)
        grid = [(128, 128), (256, 256), kernel_tune.STATIC_BLOCKS,
                choice.blocks]
        budget = int(kernel_tune._VMEM_FRACTION * kernel_tune.hw.VMEM_BYTES)
        grid = [g for g in dict.fromkeys(grid)
                if kernel_tune.vmem_plan_bytes(spec.combine, q, *g)
                <= budget]

        timed = {}
        for be, br in grid:
            t = _time(lambda: gab_fused(spec, sv, a, b, dst, old, None, nr,
                                        row_cap, block_e=be, block_r=br),
                      iters=2 if smoke else 3)
            timed[(be, br)] = t
            emit(f"kern.fused.{app}.BE{be}_BR{br}", t * 1e6,
                 f"Medges_per_s={edge_cap/t/1e6:.2f}")
        best = min(timed, key=timed.get)
        t_pick = timed[choice.blocks]
        t_static = timed[kernel_tune.STATIC_BLOCKS]
        gap = t_pick / choice.roofline_s
        emit(f"kern.fused.{app}.model_pick", t_pick * 1e6,
             f"BE={choice.block_e};BR={choice.block_r}"
             f";stack={choice.stack_size};bound={choice.bound}"
             f";ceiling_edges_per_s={choice.edges_per_s:.3e}"
             f";roofline_gap={gap:.1f}x"
             f";grid_best=BE{best[0]}_BR{best[1]}")
        results[app] = {
            "q": q, "edge_cap": edge_cap, "row_cap": row_cap,
            "pick": list(choice.blocks), "stack_size": choice.stack_size,
            "bound": choice.bound,
            "predicted_s": choice.predicted_s,
            "roofline_s": choice.roofline_s,
            "ceiling_edges_per_s": choice.edges_per_s,
            "measured_pick_s": t_pick,
            "measured_static_s": t_static,
            "measured_roofline_gap": gap,
            "grid": {f"{be}x{br}": t for (be, br), t in timed.items()},
            "grid_best": list(best),
        }
        # the model must never *predict* worse than the static default...
        static_cost = kernel_tune.tile_cost(
            spec.combine, q, edge_cap, row_cap, *kernel_tune.STATIC_BLOCKS)
        assert choice.predicted_s <= static_cost.predicted_s, app
        # ...and on real hardware the measured pick must match/beat it
        if jax.default_backend() == "tpu":
            assert t_pick <= t_static * noise_tol, (
                f"{app}: autotuned {choice.blocks} measured {t_pick:.4f}s "
                f"vs static {kernel_tune.STATIC_BLOCKS} {t_static:.4f}s")
    _save_kernels("kernel_fused_sweep", {
        "smoke": smoke,
        "backend": jax.default_backend(),
        "measured_gate": jax.default_backend() == "tpu",
        "bandwidth_bytes_per_s": kernel_tune.measured_bandwidth(),
        "apps": results,
    })


ALL = [bench_segment_sum, bench_compact, bench_gab_superstep,
       bench_kernel_fused]
