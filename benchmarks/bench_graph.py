"""Paper-figure benchmarks for the GraphH engine itself.

  Fig 5    partition balance (edge/vertex distribution across tiles)
  Table V  tile compression ratio + throughput per mode
  Fig 8    cache modes: execution time + hit ratio vs capacity
  Fig 9    dense/sparse/hybrid network traffic (+ compression)
  Fig 10   PageRank time/superstep vs server count (+ baselines)
  Fig 11   SSSP   time/superstep vs server count (+ baselines)
  Fig 7    AA vs OD expected memory model (Eq. 4/5)
  Tab III  measured cost-model table across engines
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, make_store, rmat_arrays

NV, NE = 60_000, 600_000
TILE = 30_000


def bench_partition_fig5():
    from repro.graphio import formats

    store = make_store(NV, NE, TILE)
    plan = store.load_plan()
    e = plan.edges_per_tile
    rows = np.diff(plan.splitter)
    emit("fig5.partition.tiles", 0, f"P={plan.num_tiles}")
    emit("fig5.partition.edge_cv", 0,
         f"cv={e.std()/e.mean():.4f} max_over_mean={e.max()/e.mean():.3f}")
    emit("fig5.partition.vertex_cv", 0,
         f"cv={rows.std()/max(rows.mean(),1e-9):.3f} (vertices uneven by design)")


def bench_compression_tablev():
    from repro.graphio import formats

    store = make_store(NV, NE, TILE)
    blob = formats.decompress_blob(store.read_tile_blob(0), store.disk_mode)
    for mode, (name, _) in formats.MODE_CODECS.items():
        t0 = time.perf_counter()
        comp = formats.compress_blob(blob, mode)
        tc = time.perf_counter() - t0
        t0 = time.perf_counter()
        formats.decompress_blob(comp, mode)
        td = time.perf_counter() - t0
        ratio = len(blob) / len(comp)
        emit(f"tableV.compress.{name}", tc * 1e6,
             f"ratio={ratio:.2f} decomp_MBps={len(blob)/1e6/max(td,1e-9):.0f}")


def bench_cache_fig8():
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    store = make_store(NV, NE, TILE, disk_mode=1)
    total = sum(store.tile_disk_bytes(t) for t in range(store.load_plan().num_tiles))
    for frac in (0.25, 0.5, 1.0):
        for mode in (1, 2, 3, 4):
            eng = OutOfCoreEngine(store, EngineConfig(
                num_servers=2, cache_capacity_bytes=int(total * frac / 2),
                cache_mode=mode, max_supersteps=6, tile_skipping=False))
            res = eng.run(PageRank())
            h = res.history[-1]
            emit(f"fig8.cache.mode{mode}.cap{int(frac*100)}pct",
                 res.mean_superstep_seconds() * 1e6,
                 f"hit={h.cache_hit_ratio:.2f} disk_MB={h.disk_bytes_read/1e6:.1f}")
    # auto mode selection
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=2, cache_capacity_bytes=int(total * 0.3 / 2),
        cache_mode="auto", max_supersteps=3))
    emit("fig8.cache.auto_mode_selected", 0, f"mode={eng.cache_mode}")


def bench_cache_tiers():
    """Paper Fig. 11-style capacity-vs-runtime curve for the edge-cache
    policies (DESIGN.md §8): at each cache capacity (fraction of the on-disk
    working set), compare the paper's single-mode LRU against the adaptive
    tiered and cost-aware policies — wall time per superstep, hit ratio, and
    per-tier residency.  Small tiles + compressed disk tier so misses pay a
    real decompress cost."""
    from benchmarks import common
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    if common.SMOKE:
        nv, ne, tile, fracs, steps = 8_000, 60_000, 1024, (0.25,), 3
    else:
        nv, ne, tile, fracs, steps = NV, NE, 8192, (0.125, 0.25, 0.5), 6
    store = make_store(nv, ne, tile, disk_mode=3)
    plan = store.load_plan()
    total = sum(store.tile_disk_bytes(t) for t in range(plan.num_tiles))
    for frac in fracs:
        for policy in ("lru", "tiered", "cost-aware"):
            eng = OutOfCoreEngine(store, EngineConfig(
                num_servers=2, cache_capacity_bytes=int(total * frac / 2),
                cache_mode="auto", cache_policy=policy,
                tile_skipping=False, max_supersteps=steps))
            res = eng.run(PageRank())
            h = res.history[-1]
            tiers = "/".join(f"{k}:{v['tiles']}"
                             for k, v in sorted(h.cache_tiers.items()))
            emit(f"cache_tiers.{policy}.cap{int(frac*100)}pct",
                 res.mean_superstep_seconds() * 1e6,
                 f"hit={h.cache_hit_ratio:.2f} "
                 f"promo={sum(x.cache_promotions for x in res.history)} "
                 f"demo={sum(x.cache_demotions for x in res.history)} "
                 f"tiers={tiers}")


def bench_comm_fig9():
    from repro.core.apps import SSSP, PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    store = make_store(NV, NE, TILE, weighted=True)
    for mode in ("dense", "sparse", "hybrid"):
        eng = OutOfCoreEngine(store, EngineConfig(
            num_servers=4, comm_mode=mode, max_supersteps=40,
            comm_compressor="none"))
        res = eng.run(SSSP(source=0))
        net = sum(h.network_bytes for h in res.history)
        emit(f"fig9.comm.sssp.{mode}", res.mean_superstep_seconds() * 1e6,
             f"net_MB={net/1e6:.2f} supersteps={res.supersteps}")
    for comp in ("none", "zstd-1", "zstd-3"):
        eng = OutOfCoreEngine(store, EngineConfig(
            num_servers=4, comm_mode="hybrid", comm_compressor=comp,
            max_supersteps=6))
        res = eng.run(PageRank())
        net = sum(h.network_bytes for h in res.history)
        raw = sum(h.raw_bytes * 3 for h in res.history)  # *(N-1)
        emit(f"fig9.comm.pr_compress.{comp}",
             res.mean_superstep_seconds() * 1e6,
             f"net_MB={net/1e6:.2f} raw_MB={raw/1e6:.2f}")


def _engine_run(app, servers, store):
    from repro.core.apps import SSSP, PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    prog = PageRank() if app == "pagerank" else SSSP(source=0)
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=servers, max_supersteps=10 if app == "pagerank" else 60))
    return eng.run(prog)


def bench_pagerank_fig10():
    store = make_store(NV, NE, TILE)
    for n in (1, 2, 4, 8):
        res = _engine_run("pagerank", n, store)
        emit(f"fig10.pagerank.graphh.N{n}",
             res.mean_superstep_seconds() * 1e6,
             f"supersteps={res.supersteps}")
    _baselines_point("pagerank")


def bench_sssp_fig11():
    store = make_store(NV, NE, TILE, weighted=True)
    for n in (1, 2, 4, 8):
        res = _engine_run("sssp", n, store)
        emit(f"fig11.sssp.graphh.N{n}",
             res.mean_superstep_seconds() * 1e6,
             f"supersteps={res.supersteps}")
    _baselines_point("sssp")


def _baselines_point(app):
    from repro.core.apps import SSSP, PageRank
    from repro.core.baselines import ENGINES

    src, dst, val = rmat_arrays(NV, NE, weighted=(app == "sssp"))
    prog = PageRank() if app == "pagerank" else SSSP(source=0)
    fig = "fig10" if app == "pagerank" else "fig11"
    for name, cls in ENGINES.items():
        eng = cls(src, dst, val, NV, num_servers=4)
        res = eng.run(prog, max_supersteps=8 if app == "pagerank" else 40)
        net = sum(h.network_bytes for h in res.history)
        disk = sum(h.disk_read_bytes + h.disk_write_bytes for h in res.history)
        emit(f"{fig}.{app}.{name}.N4", res.mean_superstep_seconds() * 1e6,
             f"net_MB={net/1e6:.1f} disk_MB={disk/1e6:.1f}")


def bench_memory_fig7():
    """Eq. 4/5: expected per-server memory, AA vs OD, paper's four graphs."""
    graphs = {  # |V|, d_avg  (paper Table I)
        "twitter-2010": (42e6, 35.3),
        "uk-2007": (134e6, 41.2),
        "uk-2014": (788e6, 60.4),
        "eu-2015": (1.1e9, 85.7),
    }
    for name, (v, d) in graphs.items():
        for n in (9, 16, 48):
            aa = 20 * v                                   # Size(Vertex,Msg)=20B
            od = 24 * v * ((1 - np.exp(-d / n)) + 1.0 / n)
            emit(f"fig7.memory.{name}.N{n}", 0,
                 f"AA_GB={aa/1e9:.1f} OD_GB={od/1e9:.1f} AA_wins={aa<od}")


def bench_costmodel_tableiii():
    """Measured per-superstep cost rows across all engines (PageRank)."""
    from repro.core.apps import PageRank
    from repro.core.baselines import ENGINES
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    store = make_store(NV, NE, TILE)
    eng = OutOfCoreEngine(store, EngineConfig(num_servers=4, max_supersteps=4,
                                              cache_capacity_bytes=1 << 26))
    res = eng.run(PageRank())
    h = res.history[2]
    emit("tableIII.graphh", res.mean_superstep_seconds() * 1e6,
         f"net_MB={h.network_bytes/1e6:.2f} disk_MB={h.disk_bytes_read/1e6:.2f}")
    src, dst, _ = rmat_arrays(NV, NE)
    for name, cls in ENGINES.items():
        e = cls(src, dst, None, NV, num_servers=4)
        r = e.run(PageRank(), max_supersteps=4)
        hh = r.history[2]
        emit(f"tableIII.{name}", r.mean_superstep_seconds() * 1e6,
             f"net_MB={hh.network_bytes/1e6:.2f} "
             f"disk_MB={(hh.disk_read_bytes+hh.disk_write_bytes)/1e6:.2f}")


def bench_pipeline_overlap():
    """Serial vs pipelined superstep engine (DESIGN.md §7): wall time and
    disk-stall fraction under real I/O pressure (compressed disk tier,
    cache far smaller than the working set, misses every superstep)."""
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    store = make_store(NV, NE, 8192, disk_mode=3)
    plan = store.load_plan()
    total = sum(store.tile_disk_bytes(t) for t in range(plan.num_tiles))
    cap = int(total * 0.15)

    results = {}
    for pipe in (False, True):
        eng = OutOfCoreEngine(store, EngineConfig(
            num_servers=2, cache_capacity_bytes=cap, cache_mode=3,
            tile_skipping=False, max_supersteps=6,
            pipeline=pipe, prefetch_depth=8, prefetch_workers=2,
            stack_size=4))
        res = eng.run(PageRank())
        results[pipe] = res
        # like RunResult.mean_superstep_seconds: a 1-superstep run falls
        # back to its only superstep instead of np.mean over an empty slice
        hs = res.history[1:] or res.history
        stall_ms = 1e3 * np.mean([h.stall_seconds for h in hs])
        hidden_ms = 1e3 * np.mean([h.io_hidden_seconds for h in hs])
        emit(f"pipeline.pagerank.{'pipelined' if pipe else 'serial'}",
             res.mean_superstep_seconds() * 1e6,
             f"stall_frac={res.disk_stall_fraction():.2f} "
             f"stall_ms={stall_ms:.1f} io_hidden_ms={hidden_ms:.1f}")
    ser, pip = results[False], results[True]
    # disk-stall reduction = I/O busy time moved off the critical path:
    # the serial engine stalls for ~all of its I/O, the pipelined engine
    # only for the residue the prefetcher couldn't hide.
    stall_red = (np.mean([h.stall_seconds / max(h.io_busy_seconds, 1e-9)
                          for h in ser.history[1:] or ser.history])
                 - np.mean([h.stall_seconds / max(h.io_busy_seconds, 1e-9)
                            for h in pip.history[1:] or pip.history]))
    emit("pipeline.pagerank.speedup", 0,
         f"x{ser.mean_superstep_seconds()/max(pip.mean_superstep_seconds(),1e-9):.2f} "
         f"stall_per_io_reduced={stall_red:.2f}")


def bench_multi_query():
    """Q-sweep for the multi-query GAB layer (DESIGN.md §9): batch Q
    personalized-PageRank instances into one edge pass and report tile-I/O
    bytes per query and wall-clock per query vs Q independent runs.  The
    paper's dominant cost — streaming every tile from the disk tier each
    superstep — is paid once per superstep regardless of Q, so per-query
    I/O should fall ~1/Q (modulo slower stragglers keeping late supersteps
    alive after query retirement)."""
    from benchmarks import common
    from repro.core.apps import PersonalizedPageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    if common.SMOKE:
        nv, ne, tile, qs, steps = 8_000, 60_000, 1024, (1, 4), 5
    else:
        nv, ne, tile, qs, steps = NV, NE, 8192, (1, 8, 32, 128), 8
    store = make_store(nv, ne, tile, disk_mode=3)
    plan = store.load_plan()
    total = sum(store.tile_disk_bytes(t) for t in range(plan.num_tiles))
    rng = np.random.default_rng(0)
    all_seeds = tuple(int(v) for v in rng.choice(nv, size=max(qs), replace=False))

    # Fixed superstep horizon for every Q: per-*run* I/O would conflate
    # amortization with per-seed convergence speed (a lone PPR query can
    # retire in a handful of supersteps; a 128-batch runs as long as its
    # slowest member).  Per-superstep tile I/O is the paper-faithful cost
    # unit and must be flat in Q.
    def run_q(seeds):
        eng = OutOfCoreEngine(store, EngineConfig(
            num_servers=2, cache_capacity_bytes=int(total * 0.25 / 2),
            cache_mode="auto", tile_skipping=False, max_supersteps=steps))
        t0 = time.perf_counter()
        res = eng.run(PersonalizedPageRank(seeds=seeds))
        dt = time.perf_counter() - t0
        ss = max(res.supersteps, 1)
        io_ss = sum(h.disk_bytes_read for h in res.history) / ss
        return res, dt / ss, io_ss

    _, t1, io1 = run_q((all_seeds[0],))
    emit("multi_query.q1", t1 * 1e6,
         f"io_MB_per_superstep={io1/1e6:.2f} (baseline)")
    for q in qs[1:]:
        res, tq, ioq = run_q(all_seeds[:q])
        emit(f"multi_query.q{q}", tq * 1e6,
             f"io_MB_per_superstep={ioq/1e6:.2f} "
             f"io_MB_per_ss_per_query={ioq/q/1e6:.3f} "
             f"ms_per_ss_per_query={tq/q*1e3:.1f} "
             f"io_amortization={io1*q/max(ioq,1):.1f}x "
             f"time_amortization={t1*q/max(tq,1e-9):.1f}x")


def bench_ooc_vstate():
    """Memory-budget sweep for the interval-sharded out-of-core vertex
    state (DESIGN.md §10).  A locality-structured (banded) graph makes
    tile source-interval footprints differ, a multi-query PPR batch makes
    the [V, Q] vertex footprint the dominant memory term, and the vertex
    budget sweeps down to 10% of it.  At each budget, compare
    interval-aware co-scheduling against interval-oblivious ordering:
    faults (interval blocks decoded back from warm/cold), bytes faulted
    in, bytes spilled to the disk tier, and wall time.  Results must be
    (and are, tests/test_vstate.py) bit-identical to the in-memory run."""
    from benchmarks import common
    from repro.core.apps import PersonalizedPageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    if common.SMOKE:
        nv, ne, tile, q, steps, budgets = 8_000, 60_000, 512, 8, 3, (0.25,)
    else:
        nv, ne, tile, q, steps, budgets = NV, NE, 4096, 32, 6, (0.5, 0.25, 0.1)
    store = make_store(nv, ne, tile, disk_mode=3, graph="banded",
                       num_intervals=16)
    plan = store.load_plan()
    # Edge cache under real pressure too: the interval-oblivious baseline
    # (cache-hit-first, §8) then reorders resident-edge-tiles first and
    # scrambles src-interval locality — the *joint* residency problem the
    # co-scheduler exists for.
    edge_total = sum(store.tile_disk_bytes(t) for t in range(plan.num_tiles))
    cache_cap = int(edge_total * 0.25 / 2)
    rng = np.random.default_rng(0)
    seeds = tuple(int(v) for v in rng.choice(nv, size=q, replace=False))
    # full vertex footprint: value [V,Q] + seed_mass [V,Q] + inv_out_degree [V]
    vbytes = nv * 4 * (2 * q + 1)

    def run(budget, order):
        eng = OutOfCoreEngine(store, EngineConfig(
            num_servers=2, cache_capacity_bytes=cache_cap, cache_mode="auto",
            tile_skipping=False, max_supersteps=steps,
            vertex_memory_budget=budget, interval_aware_order=order))
        res = eng.run(PersonalizedPageRank(seeds=seeds))
        faults = sum(h.vstate_faults for h in res.history)
        spill = sum(h.vstate_spill_bytes for h in res.history)
        load = sum(h.vstate_load_bytes for h in res.history)
        return res, faults, spill, load

    ref = OutOfCoreEngine(store, EngineConfig(
        num_servers=2, cache_capacity_bytes=cache_cap, cache_mode="auto",
        tile_skipping=False, max_supersteps=steps)).run(
            PersonalizedPageRank(seeds=seeds))
    emit("ooc_vstate.in_memory", ref.mean_superstep_seconds() * 1e6,
         f"vertex_MB={vbytes/1e6:.1f} (fully resident baseline)")
    for frac in budgets:
        budget = int(vbytes * frac)
        for order, tag in ((True, "interval"), (False, "naive")):
            res, faults, spill, load = run(budget, order)
            emit(f"ooc_vstate.bud{int(frac*100)}pct.{tag}",
                 res.mean_superstep_seconds() * 1e6,
                 f"faults={faults} load_MB={load/1e6:.1f} "
                 f"spill_MB={spill/1e6:.1f} "
                 f"identical={np.array_equal(res.values, ref.values)}")


def bench_scheduler():
    """Beyond-paper: straggler mitigation makespan (DESIGN.md §5)."""
    from repro.core.partition import assign_tiles
    from repro.runtime.scheduler import WorkStealingScheduler, simulate_superstep

    rng = np.random.default_rng(0)
    edges = rng.uniform(100, 1000, 256)
    speeds = np.ones(16)
    speeds[::5] = 0.3                              # stragglers
    static = max(sum(edges[t] for t in assign_tiles(256, 16)[s]) / speeds[s]
                 for s in range(16))
    sched = WorkStealingScheduler(assign_tiles(256, 16), edges)
    dyn = simulate_superstep(sched, speeds, lambda t: edges[t])
    emit("sched.straggler.makespan", 0,
         f"static={static:.0f} dynamic={dyn['makespan']:.0f} "
         f"speedup={static/dyn['makespan']:.2f}x steals={dyn['steals']}")


ALL = [bench_partition_fig5, bench_compression_tablev, bench_cache_fig8,
       bench_cache_tiers, bench_comm_fig9, bench_pagerank_fig10,
       bench_sssp_fig11, bench_memory_fig7, bench_costmodel_tableiii,
       bench_pipeline_overlap, bench_scheduler, bench_multi_query,
       bench_ooc_vstate]
