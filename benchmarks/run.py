# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#
#   PYTHONPATH=src python -m benchmarks.run [--only fig8,fig10] [--quick]
#
# Sections:
#   bench_graph    — paper Figs 5/7/8/9/10/11, Tables III/V + scheduler
#   bench_cluster  — multi-process cluster runtime: comm-mode wire bytes
#                    sweep + N-server scaling (JSON artifact)
#   bench_serve_graph — online graph-query serving: p50/p99 latency +
#                    queries/sec vs q_slots and offered QPS (JSON artifact)
#   bench_serve_http — the stdlib HTTP frontend over a real socket:
#                    client-observed p50/p99 vs offered QPS + the DRR
#                    fairness ratio under 10:1 tenant skew (JSON artifact)
#   bench_kernels  — Pallas kernel + GAB superstep throughput
#   bench_train    — LM train-step throughput (CPU, reduced configs)
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter on bench names")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes (CI: exercise the code paths, "
                         "not the numbers)")
    args = ap.parse_args()

    from benchmarks import (bench_cluster, bench_graph, bench_kernels,
                            bench_serve_graph, bench_serve_http,
                            bench_train, common)

    common.SMOKE = args.smoke

    fns = (bench_graph.ALL + bench_cluster.ALL + bench_serve_graph.ALL
           + bench_serve_http.ALL + bench_kernels.ALL + bench_train.ALL)
    if args.only:
        keys = args.only.split(",")
        fns = [f for f in fns if any(k in f.__name__ for k in keys)]
    print("name,us_per_call,derived")
    failures = 0
    for fn in fns:
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{fn.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
        print(f"# {fn.__name__} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
